"""Completion-queue session engine: per-stream ordering, cross-stream
overlap on PCIe, dependency-token barriers, UART tick-equivalence vs the
synchronous session, and end-to-end determinism."""
import pytest

from repro.core.channel import PcieChannel, UartChannel
from repro.core.cq import AsyncHtpSession, CompletionToken
from repro.core.runtime import FaseRuntime
from repro.core.session import HtpSession, HtpTransaction
from repro.core.target.pysim import PySim
from repro.core.workloads import build, graphgen


def _ctx_save(cpu):
    txn = HtpTransaction()
    for i in range(1, 32):
        txn.reg_read(cpu, i, "ctxsw")
    return txn


def _fault_batch(cpu, ppn):
    txn = HtpTransaction().page_set(cpu, ppn, 0, "pagefault")
    txn.mem_write(cpu, 8 * ppn, (ppn << 10) | 1, "pagefault")
    txn.flush_tlb(cpu, "pagefault")
    return txn


# ---------------------------------------------------------------------------
# ordering + overlap
# ---------------------------------------------------------------------------
def test_stream_completions_are_ordered_on_pcie():
    """A stream is an ordering domain: completions never invert, even when
    a big controller tail (PageS) is followed by a tiny request."""
    sess = AsyncHtpSession(PySim(1, 1 << 20), PcieChannel())
    r1 = sess.submit(_fault_batch(0, 3), 0, stream=0)
    r2 = sess.submit(HtpTransaction().reg_read(0, 1), 0, stream=0)
    assert r1.ticks == sorted(r1.ticks)
    assert r2.done >= r1.done
    assert [c.token.seq for c in sess.cq.drain()] == [1, 2]


def test_cross_stream_overlap_hides_pcie_latency():
    """Independent per-core streams submitted at the same tick share the
    doorbell/setup latency; the same trace through the synchronous
    session pays it serially."""
    def run(cls):
        t = PySim(4, 1 << 20)
        sess = cls(t, PcieChannel())
        done = 0
        for cpu in range(4):
            done = max(done, sess.submit(_ctx_save(cpu), 0,
                                         stream=cpu).done)
        return done, sess
    sync_done, _ = run(HtpSession)
    async_done, sess = run(AsyncHtpSession)
    lat = PcieChannel().latency_ticks
    assert async_done <= sync_done - 3 * lat + 3  # 3 setups overlapped
    assert sess.cqstats.coalesced >= 3
    assert sess.cqstats.latency_hidden >= 3 * lat - 3


def test_inflight_depth_gates_submission():
    """With depth=1 nothing overlaps: the engine degrades to one
    transaction in flight at a time."""
    def run(depth):
        sess = AsyncHtpSession(PySim(4, 1 << 20), PcieChannel(),
                               depth=depth, coalesce_ticks=0)
        done = 0
        for cpu in range(4):
            done = max(done, sess.submit(_fault_batch(cpu, 2 + cpu), 0,
                                         stream=cpu).done)
        return done, sess
    d1, s1 = run(1)
    d8, s8 = run(8)
    assert s1.cqstats.depth_stalls >= 3
    assert s8.cqstats.depth_stalls == 0
    assert d8 <= d1


# ---------------------------------------------------------------------------
# dependency tokens
# ---------------------------------------------------------------------------
def test_dependency_token_barriers():
    sess = AsyncHtpSession(PySim(2, 1 << 20), PcieChannel())
    r1 = sess.submit(_fault_batch(0, 3), 0, stream=0)
    assert isinstance(r1.token, CompletionToken)
    assert r1.token.tick == r1.done
    # without the token, stream 1 would start immediately; with it, the
    # dependent transaction may not issue before r1 completes
    r2 = sess.submit(HtpTransaction().reg_read(1, 1), 0, stream=1,
                     deps=(r1.token,))
    assert r2.done >= r1.done + sess.channel.latency_ticks
    # the sync session honours the same deps= surface
    ssess = HtpSession(PySim(1, 1 << 20), UartChannel())
    g1 = ssess.submit(HtpTransaction().reg_read(0, 1), 0)
    tok = CompletionToken("x", 1, g1.done + 12345)
    g2 = ssess.submit(HtpTransaction().reg_read(0, 2), 0, deps=(tok,))
    assert g2.done > g1.done + 12345


def test_none_deps_are_ignored():
    sess = AsyncHtpSession(PySim(1, 1 << 20), UartChannel())
    r = sess.submit(HtpTransaction().reg_read(0, 1), 7, deps=(None,))
    assert r.done >= 7


# ---------------------------------------------------------------------------
# UART tick-equivalence (golden behaviour from test_session.py)
# ---------------------------------------------------------------------------
def test_uart_trace_tick_identical_to_sync_session():
    """Same transaction trace, serial link: the async engine must produce
    byte-for-byte and tick-for-tick the synchronous session's results."""
    def trace(sess):
        out = []
        at = 0
        for cpu in (0, 1):
            res = sess.submit(_ctx_save(cpu), at, stream=cpu)
            out.append((res.ticks, res.done))
            at = res.done
        res = sess.submit(_fault_batch(0, 5), at, stream=0)
        out.append((res.ticks, res.done))
        res = sess.submit(HtpTransaction().tick().utick(0), res.done)
        out.append((res.ticks, res.done))
        return out, sess.channel.total_bytes, \
            dict(sess.channel.bytes_by_cat), sess.stats.uart_ticks
    got_sync = trace(HtpSession(PySim(2, 1 << 20), UartChannel()))
    got_async = trace(AsyncHtpSession(PySim(2, 1 << 20), UartChannel()))
    assert got_sync == got_async


@pytest.mark.parametrize("wl", ["hello"])
def test_uart_runtime_end_to_end_tick_identical(wl):
    reps = {}
    for sess in ("sync", "async"):
        rt = FaseRuntime(PySim(2, 1 << 22), mode="fase", link="uart",
                         session=sess)
        rt.load(build(wl), [wl])
        reps[sess] = rt.run(max_ticks=1 << 34)
    s, a = reps["sync"], reps["async"]
    assert (s.ticks, s.traffic_total, s.stall, s.traffic) == \
        (a.ticks, a.traffic_total, a.stall, a.traffic)
    assert s.stdout == a.stdout


# ---------------------------------------------------------------------------
# end-to-end pcie overlap + determinism
# ---------------------------------------------------------------------------
def test_pcie_async_runtime_not_slower_and_deterministic():
    g = graphgen.rmat(5, 8, weights=True)

    def run(sess):
        rt = FaseRuntime(PySim(4, 1 << 23), mode="fase", link="pcie",
                         session=sess)
        rt.load(build("bc"), ["bc", "g.bin", "4", "1"],
                files={"g.bin": g})
        return rt.run(max_ticks=1 << 36)

    sync_rep = run("sync")
    async_rep = run("async")
    again = run("async")
    # determinism across repeated runs: identical modelled state
    assert (async_rep.ticks, async_rep.traffic_total, async_rep.cq) == \
        (again.ticks, again.traffic_total, again.cq)
    assert async_rep.stdout == again.stdout
    # overlap: the queue-pair engine hides setup latency on the
    # latency-dominated link (strictly fewer total ticks)
    assert async_rep.cq["latency_hidden"] > 0
    assert async_rep.ticks < sync_rep.ticks
    # byte accounting is engine-independent
    assert async_rep.traffic_total == sync_rep.traffic_total


def test_serving_command_batch_on_shared_session():
    """Layer-B serving traffic shares the Layer-A session: virtual
    requests occupy the link and account bytes but never touch the
    target."""
    from repro.serving.htp import CommandBatch
    t = PySim(2, 1 << 20)
    sess = AsyncHtpSession(t, PcieChannel())
    satp_before = list(t.satp)
    r1 = sess.submit(_ctx_save(0), 0, stream=0)
    cb = CommandBatch.empty(slots=2, pages=4)
    cb.override[0] = 42
    cb.page_zeros = [5]
    r2 = sess.submit(cb.to_transaction(), 0, stream="serve")
    assert t.satp == satp_before            # virtual: no target effect
    assert t.pc[0] == 0                     # Redirect analogue not applied
    assert sess.channel.bytes_by_cat["sys:block_tables"] > 0
    # one wire: the serving batch queued behind / overlapped with the
    # runtime transaction on the same modelled link
    assert sess.stats.transactions == 2
    assert {c.token.stream for c in sess.cq.drain()} == {0, "serve"}
    assert r2.done > 0 and r1.done > 0
