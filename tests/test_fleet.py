"""Fleet layer: (device, hart) stream routing, placement-policy
correctness, cross-run determinism, single-device tick-equivalence, the
device lifecycle (billed provisioning, live job migration, serving slot
migration), and the satellite features that ride the same PRs
(speculative arg prefetch, the sync ctrl_free backport, serving fleet
sharding)."""
import pytest

from repro.core.channel import PcieChannel, UartChannel
from repro.core.cq import AsyncHtpSession
from repro.core.fleet import (Device, FleetRouter, FleetRuntime, Job,
                              make_policy)
from repro.core.fleet.placement import image_key_of, stable_hash
from repro.core.runtime import FaseRuntime
from repro.core.session import HtpSession, HtpTransaction
from repro.core.target.pysim import PySim
from repro.core.workloads import build, graphgen


def _ctx_save(cpu):
    txn = HtpTransaction()
    for i in range(1, 32):
        txn.reg_read(cpu, i, "ctxsw")
    return txn


def _mk_devices(n, link="pcie", n_cores=2, mem=1 << 20):
    return [Device(i, lambda: PySim(n_cores, mem), link=link)
            for i in range(n)]


# ---------------------------------------------------------------------------
# router: (device, hart) keying, isolation, single-device equivalence
# ---------------------------------------------------------------------------
def test_router_single_device_tick_identical_to_session():
    """A one-device fleet router is a drop-in session: same transaction
    trace, same per-request ticks, bytes and completion order."""
    def trace(submit):
        out, at = [], 0
        for cpu in (0, 1):
            r = submit(_ctx_save(cpu), at, cpu)
            out.append((tuple(r.ticks), r.done))
            at = r.done
        r = submit(HtpTransaction().tick().utick(0), at, 0)
        out.append((tuple(r.ticks), r.done))
        return out
    router = FleetRouter(_mk_devices(1, link="uart"))
    sess = AsyncHtpSession(PySim(2, 1 << 20), UartChannel())
    got_fleet = trace(lambda txn, at, cpu:
                      router.submit(txn, at, stream=(0, cpu)))
    got_plain = trace(lambda txn, at, cpu:
                      sess.submit(txn, at, stream=cpu))
    assert got_fleet == got_plain
    assert router.stats()["total_bytes"] == sess.channel.total_bytes
    # bare (non-tuple) stream keys route to the first device
    r = router.submit(HtpTransaction().reg_read(0, 1), 0, stream=0)
    assert r.done > 0


def test_device_hart_stream_isolation():
    """Streams on different devices never contend: identical transactions
    submitted at the same tick on two devices complete at the same tick
    (independent wires), while two streams of ONE device serialise on its
    shared wire."""
    router = FleetRouter(_mk_devices(2))
    r0 = router.submit(_ctx_save(0), 0, stream=(0, 0))
    r1 = router.submit(_ctx_save(0), 0, stream=(1, 0))
    assert r0.done == r1.done                 # no cross-device wire
    per_dev = router.stats()["per_device"]
    assert per_dev[0]["transactions"] == per_dev[1]["transactions"] == 1
    # same trace through ONE device's two harts: the shared wire
    # serialises the second transaction's bytes behind the first
    one = FleetRouter(_mk_devices(1))
    a = one.submit(_ctx_save(0), 0, stream=(0, 0))
    b = one.submit(_ctx_save(1), 0, stream=(0, 1))
    assert b.done > a.done                    # queued, not parallel


def test_cross_device_dependency_tokens():
    """Tokens are fleet-wide time: a dep token from device 0 delays a
    device-1 submission past its completion tick."""
    router = FleetRouter(_mk_devices(2))
    r0 = router.submit(_ctx_save(0), 0, stream=(0, 0))
    r1 = router.submit(HtpTransaction().reg_read(0, 1), 0,
                       stream=(1, 0), deps=(r0.token,))
    assert r1.done >= r0.done
    assert len(router.tail_tokens()) == 2
    assert router.quiesce_tick() >= max(r0.done, r1.done)


# ---------------------------------------------------------------------------
# placement policies
# ---------------------------------------------------------------------------
def test_placement_policy_correctness():
    devs = _mk_devices(4)
    rr = make_policy("round_robin")
    order = [rr.place(None, devs).id for _ in range(6)]
    assert order == [0, 1, 2, 3, 0, 1]

    devs[0].stats.busy_ticks = 100
    devs[1].stats.busy_ticks = 5
    devs[2].stats.busy_ticks = 50
    ll = make_policy("least_loaded")
    assert ll.place(None, devs).id == 3       # untouched board wins
    devs[3].stats.busy_ticks = 500
    assert ll.place(None, devs).id == 1

    af = make_policy("affinity")
    j1, j2 = Job("hello", affinity_key="tenant-a"), \
        Job("hello", affinity_key="tenant-a")
    assert af.place(j1, devs).id == af.place(j2, devs).id   # sticky
    # keyless jobs fall back to round-robin
    ks = [af.place(Job("hello"), devs).id for _ in range(4)]
    assert ks == [0, 1, 2, 3]
    with pytest.raises(KeyError):
        make_policy("nope")


def test_affinity_hash_is_process_stable():
    # pinned values: the placement must reproduce across interpreters
    # (Python's own str hash is salted, so the policy must not use it)
    assert stable_hash("tenant-a") == 0xC2EF8128E3EB9EFB
    assert stable_hash(42) == stable_hash("42")


# ---------------------------------------------------------------------------
# fleet runtime: orchestration, determinism, equivalence, scaling
# ---------------------------------------------------------------------------
def test_single_device_fleet_tick_identical_to_async_runtime():
    """Acceptance contract: a 1-device UART fleet reproduces a plain
    async FaseRuntime tick for tick, byte for byte."""
    fr = FleetRuntime(n_devices=1, make_target=lambda: PySim(2, 1 << 22),
                      link="uart")
    fr.submit(Job("hello"))
    fleet_rep = fr.run()
    jr = fleet_rep.jobs[0].report

    rt = FaseRuntime(PySim(2, 1 << 22), mode="fase", link="uart",
                     session="async")
    rt.load(build("hello"), ["hello"])
    plain = rt.run(max_ticks=1 << 40)
    assert (jr.ticks, jr.traffic_total, jr.stall, jr.traffic) == \
        (plain.ticks, plain.traffic_total, plain.stall, plain.traffic)
    assert jr.stdout == plain.stdout
    assert fleet_rep.makespan_ticks == plain.ticks


def test_fleet_determinism_across_runs():
    g = graphgen.rmat(4, 8, weights=True)

    def once():
        fr = FleetRuntime(n_devices=2,
                          make_target=lambda: PySim(1, 1 << 23),
                          link="pcie", placement="least_loaded")
        fr.submit(Job("bc", ["g.bin", "1", "1"], files={"g.bin": g}))
        fr.submit(Job("hello"), replicas=2)
        rep = fr.run()
        return ([(r.job.job_id, r.device_id, r.report.ticks)
                 for r in rep.jobs],
                rep.makespan_ticks, rep.total_bytes,
                {k: v["busy_ticks"] for k, v in rep.devices.items()})
    assert once() == once()


def test_fleet_scaling_and_report_aggregation():
    fr1 = FleetRuntime(n_devices=1, make_target=lambda: PySim(1, 1 << 22),
                       link="pcie")
    fr1.submit(Job("hello"), replicas=4)
    r1 = fr1.run()
    fr4 = FleetRuntime(n_devices=4, make_target=lambda: PySim(1, 1 << 22),
                       link="pcie")
    fr4.submit(Job("hello"), replicas=4)
    r4 = fr4.run()
    # identical independent jobs: round-robin levels the fleet exactly
    assert r4.makespan_ticks * 4 == r1.makespan_ticks
    assert r4.jobs_per_second > 3.5 * r1.jobs_per_second
    assert r4.balance == 1.0
    assert r1.total_job_ticks == r4.total_job_ticks
    assert r4.total_bytes == r1.total_bytes
    assert [r.device_id for r in r4.jobs] == [0, 1, 2, 3]
    # device stats survive the per-job queue-pair re-provisioning
    assert all(d["jobs"] == 1 for d in r4.devices.values())


def test_unknown_device_stream_key_raises():
    router = FleetRouter(_mk_devices(2))
    with pytest.raises(KeyError):
        router.submit(HtpTransaction().reg_read(0, 1), 0, stream=(5, 0))


def test_warm_fleet_reports_per_run_totals():
    """Repeat submit/run cycles: each report covers its own batch (no
    double-counted bytes, no throughput diluted by earlier runs)."""
    fr = FleetRuntime(n_devices=2, make_target=lambda: PySim(1, 1 << 22),
                      link="pcie")
    fr.submit(Job("hello"), replicas=2)
    r1 = fr.run()
    fr.submit(Job("hello"), replicas=2)
    r2 = fr.run()
    assert r2.total_bytes == r1.total_bytes
    assert r2.makespan_ticks == r1.makespan_ticks
    assert r2.jobs_per_second == r1.jobs_per_second
    assert r2.balance == r1.balance == 1.0
    # the devices dict still shows the boards' cumulative lifetime state
    assert all(d["jobs"] == 2 for d in r2.devices.values())
    # skewed clocks: a batch after an unbalanced one reports only its
    # own span, not earlier batches' occupancy on the busy board
    fr.devices[0].stats.busy_ticks += 10 * r1.makespan_ticks
    fr.submit(Job("hello"), replicas=2)
    r3 = fr.run()
    assert r3.makespan_ticks == r1.makespan_ticks
    assert r3.jobs_per_second == r1.jobs_per_second


def test_router_stats_on_finished_fleet_without_provisioning():
    """Read-only fleet accessors must report retired queue pairs'
    traffic and never re-image a device as a side effect."""
    fr = FleetRuntime(n_devices=2, make_target=lambda: PySim(1, 1 << 22),
                      link="pcie")
    fr.submit(Job("hello"), replicas=2)
    fleet_rep = fr.run()
    router = fr.router()
    st = router.stats()
    assert st["total_bytes"] == fleet_rep.total_bytes > 0
    assert all(v["transactions"] > 0 for v in st["per_device"].values())
    assert router.tail_tokens() == ()
    assert router.quiesce_tick() == 0
    assert not any(d.provisioned for d in fr.devices)   # no side effects


def test_mixed_link_fleet():
    fr = FleetRuntime(n_devices=2, make_target=lambda: PySim(1, 1 << 22),
                      links=["uart", "pcie"])
    fr.submit(Job("hello"), replicas=2)
    rep = fr.run()
    by_dev = {r.device_id: r.report for r in rep.jobs}
    assert by_dev[0].ticks > by_dev[1].ticks      # uart board is slower
    assert rep.makespan_ticks == by_dev[0].ticks


# ---------------------------------------------------------------------------
# device lifecycle: billed provisioning
# ---------------------------------------------------------------------------
def test_provisioning_charges_on_image_change_only():
    d = Device(0, lambda: PySim(1, 1 << 20), provision_us=100.0)
    assert d.provision_ticks_for("a") == 10_000      # 100 us @ 100 MHz
    d.provision("a")
    assert (d.stats.provisions, d.stats.provision_ticks,
            d.clock) == (1, 10_000, 10_000)
    d.provision("a")                                 # warm: same image
    assert d.stats.provisions == 1 and d.clock == 10_000
    assert d.provision_ticks_for("a") == 0
    d.provision("b")                                 # re-flash
    assert d.stats.provisions == 2 and d.clock == 20_000
    # default-off provisioning stays free (golden behaviour)
    free = Device(1, lambda: PySim(1, 1 << 20))
    free.provision("a")
    free.provision("b")
    assert free.stats.provisions == 0 and free.clock == 0


def test_least_loaded_provision_aware_vs_blind():
    """The aware greedy folds the flash charge it would trigger into
    the clock comparison; the blind greedy re-flashes."""
    def mk():
        return [Device(i, lambda: PySim(1, 1 << 20), provision_us=100.0)
                for i in range(2)]
    job_a, job_b = Job("hello"), Job("coremark")
    assert image_key_of(job_a) == "hello" != image_key_of(job_b)

    devs = mk()
    devs[0].provision("hello")               # warm board, 10k flash paid
    devs[0].stats.busy_ticks = 1_000         # ... and 1k of queue ahead
    aware = make_policy("least_loaded")
    blind = make_policy("least_loaded_blind")
    # aware: warm dev0 at 1k beats cold dev1 at 0 + 10k flash
    assert aware.place(job_a, devs).id == 0
    # blind: raw clocks only — picks the cold board and re-flashes
    assert blind.place(job_a, devs).id == 1
    # a different image gets no warmth credit anywhere: 1k+10k vs 0+10k
    assert aware.place(job_b, devs).id == 1


def test_fleet_runtime_bills_provisioning_end_to_end():
    fr = FleetRuntime(n_devices=2, make_target=lambda: PySim(1, 1 << 22),
                      link="pcie", placement="least_loaded",
                      provision_us=50.0)
    fr.submit(Job("hello"), replicas=4)
    rep = fr.run()
    total_prov = sum(d.stats.provisions for d in fr.devices)
    assert total_prov >= 2                      # both boards flashed once
    # same-image repeats re-use the flash: far fewer flashes than jobs
    assert total_prov < 4
    assert all(d.stats.provision_ticks ==
               d.stats.provisions * 5_000 for d in fr.devices)
    # the charge lands in the device clocks (and hence the makespan)
    assert rep.makespan_ticks > 5_000


# ---------------------------------------------------------------------------
# device lifecycle: live job migration
# ---------------------------------------------------------------------------
def _migration_fleet():
    return FleetRuntime(n_devices=2, make_target=lambda: PySim(1, 1 << 22),
                        link="pcie")


def test_migrate_preserves_output_and_bills_both_links():
    base = _migration_fleet()
    ref = base.run_job(base.devices[0], Job("hello"))

    fr = _migration_fleet()
    h = fr.start_job(Job("hello"), fr.devices[0])
    assert fr.step_job(h, pause_ticks=ref.report.ticks // 2) is None
    mig = fr.migrate(h, fr.devices[1])
    res = fr.finish_job(h)

    # functionally invisible, temporally visible
    assert res.report.stdout == ref.report.stdout
    assert res.report.exit_code == ref.report.exit_code
    assert res.report.ticks > ref.report.ticks
    # the checkpoint paid real bytes on BOTH links
    assert mig.src_bytes > 4096 * mig.pages_shipped
    assert mig.dst_bytes > 4096 * mig.pages_shipped
    assert mig.downtime_ticks > 0
    assert mig.pages_shipped == mig.pages_total > 0
    # occupancy split: source hosted the first span (no completion),
    # destination the rest (and the completed job)
    src, dst = fr.devices
    assert (src.stats.jobs, dst.stats.jobs) == (0, 1)
    assert src.stats.busy_ticks > 0 and dst.stats.busy_ticks > 0
    assert h.migrations == [mig] and h.device is dst


def test_migrate_delta_precopy_ships_less():
    base = _migration_fleet()
    ref = base.run_job(base.devices[0], Job("hello"))

    fr = _migration_fleet()
    h = fr.start_job(Job("hello"), fr.devices[0])
    fr.step_job(h, pause_ticks=ref.report.ticks // 4)
    basesnap = fr.prepare_migration(h, fr.devices[1])
    fr.step_job(h, pause_ticks=ref.report.ticks // 2)
    mig = fr.migrate(h, fr.devices[1], base=basesnap)
    res = fr.finish_job(h)

    assert res.report.stdout == ref.report.stdout
    assert mig.delta
    assert mig.pages_shipped < mig.pages_total    # only the dirty set
    # the delta's downtime restore is cheaper than a full image ship
    assert mig.dst_bytes < 4096 * mig.pages_total


def test_migrate_with_stale_precopy_falls_back_to_full_restore():
    """A pre-copied base is only delta-restorable into the exact queue
    pair it was shipped to: if the destination board ran another job in
    between (re-provisioned, same image name), migrate() must detect
    the stale base and ship the full chain — never a delta over a
    stranger's memory."""
    base = _migration_fleet()
    ref = base.run_job(base.devices[0], Job("hello"))

    fr = _migration_fleet()
    h = fr.start_job(Job("hello"), fr.devices[0])
    fr.step_job(h, pause_ticks=ref.report.ticks // 4)
    basesnap = fr.prepare_migration(h, fr.devices[1])
    # another same-image job claims (and re-provisions) the destination
    fr.run_job(fr.devices[1], Job("hello"))
    fr.step_job(h, pause_ticks=ref.report.ticks // 2)
    mig = fr.migrate(h, fr.devices[1], base=basesnap)
    res = fr.finish_job(h)
    assert res.report.stdout == ref.report.stdout
    # the restore shipped the whole image, not just the dirty delta —
    # and the report says so
    assert not mig.delta
    assert mig.pages_shipped == mig.pages_total
    assert mig.dst_bytes > 4096 * mig.pages_total


def test_migration_is_deterministic():
    def once():
        base = _migration_fleet()
        ref = base.run_job(base.devices[0], Job("hello"))
        fr = _migration_fleet()
        h = fr.start_job(Job("hello"), fr.devices[0])
        fr.step_job(h, pause_ticks=ref.report.ticks // 2)
        mig = fr.migrate(h, fr.devices[1])
        res = fr.finish_job(h)
        return (res.report.ticks, mig.src_bytes, mig.dst_bytes,
                mig.downtime_ticks, mig.pages_shipped)
    assert once() == once()


def test_migrate_requires_distinct_destination():
    fr = _migration_fleet()
    h = fr.start_job(Job("hello"), fr.devices[0])
    fr.step_job(h, pause_ticks=1000)
    with pytest.raises(AssertionError):
        fr.migrate(h, fr.devices[0])


# ---------------------------------------------------------------------------
# serving across the fleet
# ---------------------------------------------------------------------------
def test_serving_command_batches_shard_across_devices():
    from repro.serving.htp import CommandBatch
    router = FleetRouter(_mk_devices(2))
    single = AsyncHtpSession(None, PcieChannel())
    cb = CommandBatch.empty(slots=4, pages=8)
    cb.override[:] = 7
    cb.page_zeros = [3, 5]
    # shard slots 0,2 -> dev0 and 1,3 -> dev1 the way ServeEngine does
    for k in range(2):
        slots = [k, k + 2]
        sub = CommandBatch(override=cb.override[slots], eos=cb.eos[slots],
                           max_lens=cb.max_lens[slots],
                           block_tables=cb.block_tables[slots],
                           page_zeros=list(cb.page_zeros[k::2]))
        router.submit(sub.to_transaction(), 0, stream=(k, "serve"))
    single.submit(cb.to_transaction(), 0, stream="serve")
    st = router.stats()
    # byte totals and categories are preserved under sharding
    assert st["total_bytes"] == single.channel.total_bytes
    assert st["bytes_by_cat"] == dict(single.channel.bytes_by_cat)
    assert st["per_device"][0]["wire_bytes"] == \
        st["per_device"][1]["wire_bytes"]


# ---------------------------------------------------------------------------
# serving slot migration (load-aware placement across a skewed fleet)
# ---------------------------------------------------------------------------
def _serve_on_fleet(links, policy):
    from repro.configs import CONFIGS
    from repro.models import core as M
    from repro.serving.engine import Request, ServeEngine
    cfg = CONFIGS["qwen3-8b"].smoke()
    params = M.init_params(cfg, 0)
    fr = FleetRuntime(make_target=lambda: PySim(1, 1 << 20),
                      n_devices=len(links), links=list(links))
    eng = ServeEngine(cfg, params, slots=4, max_seq=128, poll_every=2,
                      fleet=fr, slot_policy=policy, rebalance_every=2)
    for i in range(6):
        eng.submit(Request(rid=i, prompt=[3 + i, 7, 11], max_new=12,
                           eos=1))
    done = eng.run()
    return eng, sorted((r.rid, tuple(r.out)) for r in done)


def test_slot_migration_moves_off_slow_board_and_keeps_tokens():
    """Skewed fleet (one board behind a far PCIe hop): the least_loaded
    slot policy migrates decode slots off the slow board — paying
    block-table + KV re-shipment on both links — and cuts the per-step
    makespan; tokens are bit-identical to sticky sharding."""
    sticky, out_s = _serve_on_fleet(["pcie", "pcie_far"], "sticky")
    ll, out_l = _serve_on_fleet(["pcie", "pcie_far"], "least_loaded")
    assert out_s == out_l                          # timing-only feature
    assert ll.slot_migrations > 0
    assert ll.traffic.by_cat["slot_migrate"] > 0   # billed, not free
    mean = lambda xs: sum(xs) / len(xs)            # noqa: E731
    assert mean(ll.step_spans) < mean(sticky.step_spans)
    # the slow board ends up holding no slots
    by_dev = dict(ll._dev_slots)
    assert by_dev[1] == [] and sorted(by_dev[0]) == [0, 1, 2, 3]


def test_slot_migration_noop_on_balanced_fleet():
    """A homogeneous fleet is a fixed point: no moves, tick-identical
    to sticky sharding."""
    sticky, out_s = _serve_on_fleet(["pcie", "pcie"], "sticky")
    ll, out_l = _serve_on_fleet(["pcie", "pcie"], "least_loaded")
    assert out_s == out_l
    assert ll.slot_migrations == 0
    assert ll.link_tick == sticky.link_tick
    assert "slot_migrate" not in ll.traffic.by_cat


# ---------------------------------------------------------------------------
# satellite: speculative syscall-arg prefetch
# ---------------------------------------------------------------------------
def test_arg_prefetch_functionally_identical_and_fewer_round_trips():
    reps = {}
    txns = {}
    for pf in (False, True):
        rt = FaseRuntime(PySim(1, 1 << 22), mode="fase", link="pcie",
                         arg_prefetch=pf)
        rt.load(build("hello"), ["hello"])
        reps[pf] = rt.run(max_ticks=1 << 34)
        txns[pf] = rt.session.stats.transactions
    assert reps[True].stdout == reps[False].stdout
    assert reps[True].exit_code == reps[False].exit_code
    assert txns[True] < txns[False]                  # fewer round trips
    assert reps[True].traffic_total > reps[False].traffic_total  # more bytes
    # the prefetched registers are billed to their own traffic category
    assert reps[True].traffic["sys:argprefetch"] > 0


def test_arg_prefetch_default_off_keeps_uart_goldens():
    rt = FaseRuntime(PySim(1, 1 << 22), mode="fase", link="uart")
    assert rt.arg_prefetch is False
    rt.load(build("hello"), ["hello"])
    rep = rt.run(max_ticks=1 << 34)
    assert "sys:argprefetch" not in rep.traffic


# ---------------------------------------------------------------------------
# satellite: sync-session per-hart ctrl_free backport
# ---------------------------------------------------------------------------
def test_ctrl_serialize_prevents_cross_transaction_overlap():
    """The overlap artefact: without the flag, a second transaction's
    controller cycles can start while the first's 1.5k-cycle PageS tail
    is still executing on the same hart.  With the flag, the hart's
    controller slice serialises them (the async engine's discipline)."""
    def run(flag):
        sess = HtpSession(PySim(1, 1 << 20), PcieChannel(),
                          ctrl_serialize=flag)
        r1 = sess.submit(HtpTransaction().page_set(0, 3, 0, "pf"), 0)
        r2 = sess.submit(HtpTransaction().reg_read(0, 1), 0)
        return r1, r2
    r1, r2 = run(False)
    assert r2.done < r1.done          # the unphysical overlap
    r1s, r2s = run(True)
    assert r2s.done >= r1s.done + 1   # serialised behind the PageS tail
    assert r1s.done == r1.done        # first transaction unchanged


def test_ctrl_serialize_default_off_is_tick_identical():
    """Flag off (the default) must keep the historical arithmetic —
    that is the UART golden-tick contract."""
    def trace(sess):
        out, at = [], 0
        for cpu in (0, 1):
            r = sess.submit(_ctx_save(cpu), at)
            out.append((tuple(r.ticks), r.done))
            at = r.done
        return out, sess.stats.uart_ticks
    base = trace(HtpSession(PySim(2, 1 << 20), UartChannel()))
    dflt = trace(HtpSession(PySim(2, 1 << 20), UartChannel(),
                            ctrl_serialize=False))
    assert base == dflt


def test_ctrl_serialize_runtime_end_to_end():
    """Runtime wiring: the flag reaches the session and the run still
    completes correctly on both engines."""
    for engine in ("sync", "async"):
        rt = FaseRuntime(PySim(1, 1 << 22), mode="fase", link="pcie",
                         session=engine, ctrl_serialize=True)
        assert rt.session.ctrl_serialize is True
        rt.load(build("hello"), ["hello"])
        rep = rt.run(max_ticks=1 << 34)
        assert b"hello from FASE target" in rep.stdout
