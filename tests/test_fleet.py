"""Fleet layer: (device, hart) stream routing, placement-policy
correctness, cross-run determinism, single-device tick-equivalence, and
the satellite features that ride the same PR (speculative arg prefetch,
the sync ctrl_free backport, serving fleet sharding)."""
import pytest

from repro.core.channel import PcieChannel, UartChannel
from repro.core.cq import AsyncHtpSession
from repro.core.fleet import (Device, FleetRouter, FleetRuntime, Job,
                              make_policy)
from repro.core.fleet.placement import stable_hash
from repro.core.runtime import FaseRuntime
from repro.core.session import HtpSession, HtpTransaction
from repro.core.target.pysim import PySim
from repro.core.workloads import build, graphgen


def _ctx_save(cpu):
    txn = HtpTransaction()
    for i in range(1, 32):
        txn.reg_read(cpu, i, "ctxsw")
    return txn


def _mk_devices(n, link="pcie", n_cores=2, mem=1 << 20):
    return [Device(i, lambda: PySim(n_cores, mem), link=link)
            for i in range(n)]


# ---------------------------------------------------------------------------
# router: (device, hart) keying, isolation, single-device equivalence
# ---------------------------------------------------------------------------
def test_router_single_device_tick_identical_to_session():
    """A one-device fleet router is a drop-in session: same transaction
    trace, same per-request ticks, bytes and completion order."""
    def trace(submit):
        out, at = [], 0
        for cpu in (0, 1):
            r = submit(_ctx_save(cpu), at, cpu)
            out.append((tuple(r.ticks), r.done))
            at = r.done
        r = submit(HtpTransaction().tick().utick(0), at, 0)
        out.append((tuple(r.ticks), r.done))
        return out
    router = FleetRouter(_mk_devices(1, link="uart"))
    sess = AsyncHtpSession(PySim(2, 1 << 20), UartChannel())
    got_fleet = trace(lambda txn, at, cpu:
                      router.submit(txn, at, stream=(0, cpu)))
    got_plain = trace(lambda txn, at, cpu:
                      sess.submit(txn, at, stream=cpu))
    assert got_fleet == got_plain
    assert router.stats()["total_bytes"] == sess.channel.total_bytes
    # bare (non-tuple) stream keys route to the first device
    r = router.submit(HtpTransaction().reg_read(0, 1), 0, stream=0)
    assert r.done > 0


def test_device_hart_stream_isolation():
    """Streams on different devices never contend: identical transactions
    submitted at the same tick on two devices complete at the same tick
    (independent wires), while two streams of ONE device serialise on its
    shared wire."""
    router = FleetRouter(_mk_devices(2))
    r0 = router.submit(_ctx_save(0), 0, stream=(0, 0))
    r1 = router.submit(_ctx_save(0), 0, stream=(1, 0))
    assert r0.done == r1.done                 # no cross-device wire
    per_dev = router.stats()["per_device"]
    assert per_dev[0]["transactions"] == per_dev[1]["transactions"] == 1
    # same trace through ONE device's two harts: the shared wire
    # serialises the second transaction's bytes behind the first
    one = FleetRouter(_mk_devices(1))
    a = one.submit(_ctx_save(0), 0, stream=(0, 0))
    b = one.submit(_ctx_save(1), 0, stream=(0, 1))
    assert b.done > a.done                    # queued, not parallel


def test_cross_device_dependency_tokens():
    """Tokens are fleet-wide time: a dep token from device 0 delays a
    device-1 submission past its completion tick."""
    router = FleetRouter(_mk_devices(2))
    r0 = router.submit(_ctx_save(0), 0, stream=(0, 0))
    r1 = router.submit(HtpTransaction().reg_read(0, 1), 0,
                       stream=(1, 0), deps=(r0.token,))
    assert r1.done >= r0.done
    assert len(router.tail_tokens()) == 2
    assert router.quiesce_tick() >= max(r0.done, r1.done)


# ---------------------------------------------------------------------------
# placement policies
# ---------------------------------------------------------------------------
def test_placement_policy_correctness():
    devs = _mk_devices(4)
    rr = make_policy("round_robin")
    order = [rr.place(None, devs).id for _ in range(6)]
    assert order == [0, 1, 2, 3, 0, 1]

    devs[0].stats.busy_ticks = 100
    devs[1].stats.busy_ticks = 5
    devs[2].stats.busy_ticks = 50
    ll = make_policy("least_loaded")
    assert ll.place(None, devs).id == 3       # untouched board wins
    devs[3].stats.busy_ticks = 500
    assert ll.place(None, devs).id == 1

    af = make_policy("affinity")
    j1, j2 = Job("hello", affinity_key="tenant-a"), \
        Job("hello", affinity_key="tenant-a")
    assert af.place(j1, devs).id == af.place(j2, devs).id   # sticky
    # keyless jobs fall back to round-robin
    ks = [af.place(Job("hello"), devs).id for _ in range(4)]
    assert ks == [0, 1, 2, 3]
    with pytest.raises(KeyError):
        make_policy("nope")


def test_affinity_hash_is_process_stable():
    # pinned values: the placement must reproduce across interpreters
    # (Python's own str hash is salted, so the policy must not use it)
    assert stable_hash("tenant-a") == 0xC2EF8128E3EB9EFB
    assert stable_hash(42) == stable_hash("42")


# ---------------------------------------------------------------------------
# fleet runtime: orchestration, determinism, equivalence, scaling
# ---------------------------------------------------------------------------
def test_single_device_fleet_tick_identical_to_async_runtime():
    """Acceptance contract: a 1-device UART fleet reproduces a plain
    async FaseRuntime tick for tick, byte for byte."""
    fr = FleetRuntime(n_devices=1, make_target=lambda: PySim(2, 1 << 22),
                      link="uart")
    fr.submit(Job("hello"))
    fleet_rep = fr.run()
    jr = fleet_rep.jobs[0].report

    rt = FaseRuntime(PySim(2, 1 << 22), mode="fase", link="uart",
                     session="async")
    rt.load(build("hello"), ["hello"])
    plain = rt.run(max_ticks=1 << 40)
    assert (jr.ticks, jr.traffic_total, jr.stall, jr.traffic) == \
        (plain.ticks, plain.traffic_total, plain.stall, plain.traffic)
    assert jr.stdout == plain.stdout
    assert fleet_rep.makespan_ticks == plain.ticks


def test_fleet_determinism_across_runs():
    g = graphgen.rmat(4, 8, weights=True)

    def once():
        fr = FleetRuntime(n_devices=2,
                          make_target=lambda: PySim(1, 1 << 23),
                          link="pcie", placement="least_loaded")
        fr.submit(Job("bc", ["g.bin", "1", "1"], files={"g.bin": g}))
        fr.submit(Job("hello"), replicas=2)
        rep = fr.run()
        return ([(r.job.job_id, r.device_id, r.report.ticks)
                 for r in rep.jobs],
                rep.makespan_ticks, rep.total_bytes,
                {k: v["busy_ticks"] for k, v in rep.devices.items()})
    assert once() == once()


def test_fleet_scaling_and_report_aggregation():
    fr1 = FleetRuntime(n_devices=1, make_target=lambda: PySim(1, 1 << 22),
                       link="pcie")
    fr1.submit(Job("hello"), replicas=4)
    r1 = fr1.run()
    fr4 = FleetRuntime(n_devices=4, make_target=lambda: PySim(1, 1 << 22),
                       link="pcie")
    fr4.submit(Job("hello"), replicas=4)
    r4 = fr4.run()
    # identical independent jobs: round-robin levels the fleet exactly
    assert r4.makespan_ticks * 4 == r1.makespan_ticks
    assert r4.jobs_per_second > 3.5 * r1.jobs_per_second
    assert r4.balance == 1.0
    assert r1.total_job_ticks == r4.total_job_ticks
    assert r4.total_bytes == r1.total_bytes
    assert [r.device_id for r in r4.jobs] == [0, 1, 2, 3]
    # device stats survive the per-job queue-pair re-provisioning
    assert all(d["jobs"] == 1 for d in r4.devices.values())


def test_unknown_device_stream_key_raises():
    router = FleetRouter(_mk_devices(2))
    with pytest.raises(KeyError):
        router.submit(HtpTransaction().reg_read(0, 1), 0, stream=(5, 0))


def test_warm_fleet_reports_per_run_totals():
    """Repeat submit/run cycles: each report covers its own batch (no
    double-counted bytes, no throughput diluted by earlier runs)."""
    fr = FleetRuntime(n_devices=2, make_target=lambda: PySim(1, 1 << 22),
                      link="pcie")
    fr.submit(Job("hello"), replicas=2)
    r1 = fr.run()
    fr.submit(Job("hello"), replicas=2)
    r2 = fr.run()
    assert r2.total_bytes == r1.total_bytes
    assert r2.makespan_ticks == r1.makespan_ticks
    assert r2.jobs_per_second == r1.jobs_per_second
    assert r2.balance == r1.balance == 1.0
    # the devices dict still shows the boards' cumulative lifetime state
    assert all(d["jobs"] == 2 for d in r2.devices.values())
    # skewed clocks: a batch after an unbalanced one reports only its
    # own span, not earlier batches' occupancy on the busy board
    fr.devices[0].stats.busy_ticks += 10 * r1.makespan_ticks
    fr.submit(Job("hello"), replicas=2)
    r3 = fr.run()
    assert r3.makespan_ticks == r1.makespan_ticks
    assert r3.jobs_per_second == r1.jobs_per_second


def test_router_stats_on_finished_fleet_without_provisioning():
    """Read-only fleet accessors must report retired queue pairs'
    traffic and never re-image a device as a side effect."""
    fr = FleetRuntime(n_devices=2, make_target=lambda: PySim(1, 1 << 22),
                      link="pcie")
    fr.submit(Job("hello"), replicas=2)
    fleet_rep = fr.run()
    router = fr.router()
    st = router.stats()
    assert st["total_bytes"] == fleet_rep.total_bytes > 0
    assert all(v["transactions"] > 0 for v in st["per_device"].values())
    assert router.tail_tokens() == ()
    assert router.quiesce_tick() == 0
    assert not any(d.provisioned for d in fr.devices)   # no side effects


def test_mixed_link_fleet():
    fr = FleetRuntime(n_devices=2, make_target=lambda: PySim(1, 1 << 22),
                      links=["uart", "pcie"])
    fr.submit(Job("hello"), replicas=2)
    rep = fr.run()
    by_dev = {r.device_id: r.report for r in rep.jobs}
    assert by_dev[0].ticks > by_dev[1].ticks      # uart board is slower
    assert rep.makespan_ticks == by_dev[0].ticks


# ---------------------------------------------------------------------------
# serving across the fleet
# ---------------------------------------------------------------------------
def test_serving_command_batches_shard_across_devices():
    from repro.serving.htp import CommandBatch
    router = FleetRouter(_mk_devices(2))
    single = AsyncHtpSession(None, PcieChannel())
    cb = CommandBatch.empty(slots=4, pages=8)
    cb.override[:] = 7
    cb.page_zeros = [3, 5]
    # shard slots 0,2 -> dev0 and 1,3 -> dev1 the way ServeEngine does
    for k in range(2):
        slots = [k, k + 2]
        sub = CommandBatch(override=cb.override[slots], eos=cb.eos[slots],
                           max_lens=cb.max_lens[slots],
                           block_tables=cb.block_tables[slots],
                           page_zeros=list(cb.page_zeros[k::2]))
        router.submit(sub.to_transaction(), 0, stream=(k, "serve"))
    single.submit(cb.to_transaction(), 0, stream="serve")
    st = router.stats()
    # byte totals and categories are preserved under sharding
    assert st["total_bytes"] == single.channel.total_bytes
    assert st["bytes_by_cat"] == dict(single.channel.bytes_by_cat)
    assert st["per_device"][0]["wire_bytes"] == \
        st["per_device"][1]["wire_bytes"]


# ---------------------------------------------------------------------------
# satellite: speculative syscall-arg prefetch
# ---------------------------------------------------------------------------
def test_arg_prefetch_functionally_identical_and_fewer_round_trips():
    reps = {}
    txns = {}
    for pf in (False, True):
        rt = FaseRuntime(PySim(1, 1 << 22), mode="fase", link="pcie",
                         arg_prefetch=pf)
        rt.load(build("hello"), ["hello"])
        reps[pf] = rt.run(max_ticks=1 << 34)
        txns[pf] = rt.session.stats.transactions
    assert reps[True].stdout == reps[False].stdout
    assert reps[True].exit_code == reps[False].exit_code
    assert txns[True] < txns[False]                  # fewer round trips
    assert reps[True].traffic_total > reps[False].traffic_total  # more bytes
    # the prefetched registers are billed to their own traffic category
    assert reps[True].traffic["sys:argprefetch"] > 0


def test_arg_prefetch_default_off_keeps_uart_goldens():
    rt = FaseRuntime(PySim(1, 1 << 22), mode="fase", link="uart")
    assert rt.arg_prefetch is False
    rt.load(build("hello"), ["hello"])
    rep = rt.run(max_ticks=1 << 34)
    assert "sys:argprefetch" not in rep.traffic


# ---------------------------------------------------------------------------
# satellite: sync-session per-hart ctrl_free backport
# ---------------------------------------------------------------------------
def test_ctrl_serialize_prevents_cross_transaction_overlap():
    """The overlap artefact: without the flag, a second transaction's
    controller cycles can start while the first's 1.5k-cycle PageS tail
    is still executing on the same hart.  With the flag, the hart's
    controller slice serialises them (the async engine's discipline)."""
    def run(flag):
        sess = HtpSession(PySim(1, 1 << 20), PcieChannel(),
                          ctrl_serialize=flag)
        r1 = sess.submit(HtpTransaction().page_set(0, 3, 0, "pf"), 0)
        r2 = sess.submit(HtpTransaction().reg_read(0, 1), 0)
        return r1, r2
    r1, r2 = run(False)
    assert r2.done < r1.done          # the unphysical overlap
    r1s, r2s = run(True)
    assert r2s.done >= r1s.done + 1   # serialised behind the PageS tail
    assert r1s.done == r1.done        # first transaction unchanged


def test_ctrl_serialize_default_off_is_tick_identical():
    """Flag off (the default) must keep the historical arithmetic —
    that is the UART golden-tick contract."""
    def trace(sess):
        out, at = [], 0
        for cpu in (0, 1):
            r = sess.submit(_ctx_save(cpu), at)
            out.append((tuple(r.ticks), r.done))
            at = r.done
        return out, sess.stats.uart_ticks
    base = trace(HtpSession(PySim(2, 1 << 20), UartChannel()))
    dflt = trace(HtpSession(PySim(2, 1 << 20), UartChannel(),
                            ctrl_serialize=False))
    assert base == dflt


def test_ctrl_serialize_runtime_end_to_end():
    """Runtime wiring: the flag reaches the session and the run still
    completes correctly on both engines."""
    for engine in ("sync", "async"):
        rt = FaseRuntime(PySim(1, 1 << 22), mode="fase", link="pcie",
                         session=engine, ctrl_serialize=True)
        assert rt.session.ctrl_serialize is True
        rt.load(build("hello"), ["hello"])
        rep = rt.run(max_ticks=1 << 34)
        assert b"hello from FASE target" in rep.stdout
